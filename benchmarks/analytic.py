"""Exact analytic FLOP / byte / collective models per (arch x shape) cell.

Why analytic: XLA's HLO cost analysis counts while/scan bodies ONCE
(verified empirically in this container: an 8-iteration scan of matmuls
reports 1 matmul of flops), so the layer-scanned train/prefill cells
under-count ~n_layers x. Decode cells match HLO within ~10% (see
EXPERIMENTS.md §Roofline). The formulas below mirror the implementation
op-for-op — including its inefficiencies (full masked causal attention =
2x useful attention FLOPs, remat recompute, MoE capacity slack) — so the
MODEL_FLOPS/impl ratio honestly exposes overheads the compiler numbers
cannot see.

All values are GLOBAL per optimizer step / forward; roofline.py divides by
chip count and peak rates.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs import get_config, shape_for

# hardware constants (v5e per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
LINK_BW = 50e9             # B/s per ICI link
CHIPS = 256                # single-pod roofline mesh
TP = 16                    # model axis
DP = 16                    # data axis


def _dense_layer_flops(cfg, tokens, attended, *, window=0):
    """Forward FLOPs for one attention+MLP layer over `tokens` tokens, each
    attending to `attended` kv positions (the IMPLEMENTATION cost: the
    baseline computes all chunks then masks)."""
    d, hd = cfg.d_model, cfg.hd
    qd, kvd = cfg.n_heads * hd, cfg.n_kv * hd
    proj = 2 * tokens * d * (qd + 2 * kvd) + 2 * tokens * qd * d
    attn = 4 * tokens * cfg.n_heads * hd * attended
    if cfg.n_experts:
        cf = 1.25
        ffn = 2 * tokens * d * cfg.n_experts  # router
        ffn += 6 * tokens * d * cfg.expert_ff * cfg.top_k * cf
    else:
        ffn = 6 * tokens * d * cfg.d_ff
    return proj + attn + ffn


def _ssm_layer_flops(cfg, tokens):
    d = cfg.d_model
    din = cfg.ssm_expand * d
    nh, hd, n, q = din // cfg.ssm_headdim, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_chunk
    proj = 2 * tokens * d * (2 * din + 2 * n + nh) + 2 * tokens * din * d
    conv = 2 * tokens * din * cfg.ssm_conv
    intra = tokens * q * (2 * n + 2 * nh * hd)          # cb + att@x per token-pair row
    inter = 4 * tokens * n * nh * hd                    # states in/out
    return proj + conv + intra + inter


def _lru_layer_flops(cfg, tokens):
    d, dl = cfg.d_model, cfg.d_lru
    branch = 2 * tokens * d * dl * 2 + 2 * tokens * dl * cfg.ssm_conv
    gates = 2 * tokens * dl * dl * 2
    out = 2 * tokens * dl * d
    mlp = 6 * tokens * d * cfg.d_ff
    return branch + gates + out + mlp


def _layer_counts(cfg):
    """(n_attn_global, n_attn_local, n_rec, n_ssm) layers."""
    if cfg.family == "ssm":
        return 0, 0, 0, cfg.n_layers
    if cfg.family == "hybrid":
        pat = cfg.block_pattern
        kinds = [pat[i % len(pat)] for i in range(cfg.n_layers)]
        n_attn = sum(1 for k in kinds if "attn" in k)
        return 0, n_attn, cfg.n_layers - n_attn, 0
    if cfg.local_global_period == 2 and cfg.sliding_window:
        return cfg.n_layers // 2, cfg.n_layers // 2, 0, 0
    return cfg.n_layers, 0, 0, 0


@dataclass
class CellModel:
    impl_flops: float          # implementation forward(+backward) FLOPs, global
    model_flops: float         # 6*N*D / 2*N*D "useful" reference
    hbm_bytes_per_chip: float  # per-device traffic per step
    coll_bytes_per_chip: float  # per-device collective traffic per step
    notes: str


def cell_model(arch: str, shape: str, *, microbatches: int = 1,
               remat: bool = True) -> CellModel:
    cfg = get_config(arch)
    sh = shape_for(shape)
    b, s, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    n = cfg.param_count()
    n_active = cfg.active_param_count()
    d = cfg.d_model
    wbytes = 2 * n  # bf16

    if cfg.family == "encdec":
        # encoder over frames + decoder over tokens
        f = cfg.enc_frames
        if kind in ("train", "prefill"):
            tokens_dec, tokens_enc = b * s, b * f
            fwd = cfg.enc_layers * _dense_layer_flops(cfg, tokens_enc, f)
            fwd += cfg.n_layers * (_dense_layer_flops(cfg, tokens_dec, s)
                                   + 4 * tokens_dec * cfg.n_heads * cfg.hd * f
                                   + 2 * tokens_dec * d * (cfg.n_heads + 2 * cfg.n_kv) * cfg.hd)
            fwd += 2 * tokens_dec * d * cfg.vocab
            d_tok = b * s
        else:
            tokens_dec = b
            fwd = cfg.n_layers * (_dense_layer_flops(cfg, tokens_dec, s)
                                  + 4 * tokens_dec * cfg.n_heads * cfg.hd * f)
            fwd += 2 * tokens_dec * d * cfg.vocab
            d_tok = b
    else:
        ng, nl, nr, ns = _layer_counts(cfg)
        if kind in ("train", "prefill"):
            tokens = b * s
            att_full = s            # baseline computes all chunks, masks
            fwd = ng * _dense_layer_flops(cfg, tokens, att_full)
            fwd += nl * _dense_layer_flops(cfg, tokens, att_full, window=cfg.sliding_window)
            fwd += nr * _lru_layer_flops(cfg, tokens)
            fwd += ns * _ssm_layer_flops(cfg, tokens)
            fwd += 2 * tokens * d * cfg.vocab
            d_tok = tokens
        else:  # decode: one token, attends to cache
            tokens = b
            att = s
            att_local = min(s, cfg.sliding_window or s)
            fwd = ng * _dense_layer_flops(cfg, tokens, att)
            fwd += nl * _dense_layer_flops(cfg, tokens, att_local, window=cfg.sliding_window)
            fwd += nr * _lru_layer_flops(cfg, tokens)
            fwd += ns * _ssm_layer_flops(cfg, tokens)
            fwd += 2 * tokens * d * cfg.vocab
            d_tok = b

    if kind == "train":
        mult = 4.0 if remat else 3.0   # fwd + 2x bwd (+1x remat refwd)
        impl = fwd * mult
        model = 6 * (n_active if cfg.n_experts else n) * d_tok
    else:
        impl = fwd
        model = 2 * (n_active if cfg.n_experts else n) * d_tok

    # ---- per-chip HBM traffic -------------------------------------------------
    tokens_local = d_tok / DP if b >= DP else d_tok
    act_unit = tokens_local * d * 2      # one bf16 activation tensor / chip
    nlayers = cfg.n_layers + cfg.enc_layers
    if kind == "train":
        w_io = 3 * microbatches * wbytes / TP          # fwd+bwd+remat reads of gathered shard
        opt_io = 20 * n / CHIPS                         # f32 m,v,p read+write
        act_io = 10 * nlayers * act_unit / microbatches * microbatches
        hbm = w_io + opt_io + act_io
    elif kind == "prefill":
        hbm = wbytes / TP + 10 * nlayers * act_unit
        # cache writes
        hbm += 2 * nlayers * tokens_local * cfg.n_kv * cfg.hd * 2 * 2
    else:  # decode: weights re-read per token + cache read
        hbm = wbytes / TP
        blocal = max(1, b // DP)
        if cfg.family == "ssm":
            din = cfg.ssm_expand * d
            state = cfg.n_layers * blocal * (din // cfg.ssm_headdim) * cfg.ssm_state * cfg.ssm_headdim * 4
            hbm += 2 * state / TP * 2
        elif cfg.family == "hybrid":
            _, nl, nr, _ = _layer_counts(cfg)
            kvb = nl * blocal * min(s, cfg.sliding_window) * cfg.n_kv * cfg.hd * 2 * 2
            lru = nr * blocal * cfg.d_lru * 4 * 2
            hbm += (kvb + lru) / TP * 2
        else:
            ng, nl, _, _ = _layer_counts(cfg)
            kvb = (ng * s + nl * min(s, cfg.sliding_window or s)) * blocal * cfg.n_kv * cfg.hd * 2 * 2
            hbm += kvb / TP   # kv heads or hd sharded over model

    # ---- per-chip collective traffic -------------------------------------------
    if kind == "train":
        ag = 2 * microbatches * wbytes / TP            # FSDP AG fwd+bwd(remat)
        rs = 4 * n / CHIPS                             # grad reduce-scatter (f32), per-chip
        tp_ar = 4 * nlayers * act_unit                 # TP activation all-reduces
        coll = ag + rs + tp_ar
    elif kind == "prefill":
        coll = 4 * nlayers * act_unit
    else:
        blocal = max(1, b // DP)
        coll = 4 * nlayers * blocal * d * 2

    return CellModel(
        impl_flops=impl,
        model_flops=model,
        hbm_bytes_per_chip=hbm,
        coll_bytes_per_chip=coll,
        notes=f"kind={kind} mb={microbatches}",
    )


def irreducible_memory_bytes(arch: str, shape: str) -> float:
    """Per-chip traffic that NO implementation of this cell can avoid:
    weights touched once (+opt state for train, +cache once for decode) and
    two activation passes per layer. The decode numerator of the roofline
    fraction (decode is intrinsically memory-bound; its score is how close
    the step sits to this floor, not to the compute roof)."""
    cfg = get_config(arch)
    sh = shape_for(shape)
    b, s, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    n = cfg.param_count()
    m = cell_model(arch, shape)
    if kind == "decode":
        return m.hbm_bytes_per_chip          # already minimal: weights + state
    d_tok = b * s
    act_unit = d_tok / DP * cfg.d_model * 2
    nlayers = cfg.n_layers + cfg.enc_layers
    base = 2 * n / TP + 2 * nlayers * act_unit
    if kind == "train":
        base += 20 * n / CHIPS
    return base


def roofline_terms(arch: str, shape: str, *, microbatches: int = 1):
    """Three roofline terms + fraction. fraction = attainable-floor time /
    max(term): floor = max(MODEL_FLOPS time, irreducible HBM time)."""
    m = cell_model(arch, shape, microbatches=microbatches)
    compute_s = m.impl_flops / CHIPS / PEAK_FLOPS
    memory_s = m.hbm_bytes_per_chip / HBM_BW
    coll_s = m.coll_bytes_per_chip / LINK_BW
    model_s = m.model_flops / CHIPS / PEAK_FLOPS
    floor_s = max(model_s, irreducible_memory_bytes(arch, shape) / HBM_BW)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(compute_s, memory_s, coll_s)
    frac = floor_s / bound if bound > 0 else 0.0
    return dict(
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        model_flops=m.model_flops, impl_flops=m.impl_flops,
        useful_ratio=m.model_flops / m.impl_flops,
        model_s=model_s, floor_s=floor_s, dominant=dominant,
        roofline_fraction=min(frac, 1.0),
        hbm_bytes_per_chip=m.hbm_bytes_per_chip,
        coll_bytes_per_chip=m.coll_bytes_per_chip,
    )
