"""Benchmark driver — one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV lines per the harness contract.
  python -m benchmarks.run [--quick] [--json PATH] [--smoke]

``--json`` additionally writes the sweep figures' rows as one uniform
long-format record list — every registered figure emits records with the
same required keys ({figure, q, engine, seconds, steps, steps_per_s,
speedup_vs_baseline}, figure-specific extras allowed), so downstream
plotting aggregates them without per-figure cases — and, on FULL runs
only, drops one ``BENCH_<figure>.json`` per figure at the repo root,
recording the perf trajectory PR over PR (quick/smoke numbers are not
comparable and never touch those records).

``--smoke`` is the CI gate: quick mode, every registered sweep figure must
run and emit schema-valid JSON (kernel/roofline sections are skipped —
they are not sweep figures).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# Registered sweep figures: (figure-name prefix emitted in records,
# module name, banner). --smoke asserts each emits >= 1 schema-valid row.
FIGURES = (
    ("fig9_throughput", "fig9_throughput",
     "Fig. 9 analogue — throughput vs lanes, 3 mixes, no GetPath"),
    ("fig10_getpath", "fig10_getpath",
     "Fig. 10 analogue — mixes + 2% GetPath (double-collect sessions)"),
    ("multiquery", "fig_multiquery",
     "Multi-query analogue — fused multi-source BFS vs vmap, Q sweep"),
    ("sharded", "fig_sharded",
     "Sharded analogue — mesh-partitioned engines vs dense (DESIGN.md §8)"),
    ("index", "fig_index",
     "Reachability index — 2-hop label fast path vs fused BFS (DESIGN.md §9)"),
    ("serving", "fig_serving",
     "Serving admission — coalesced multi-tenant ingest vs serial baseline "
     "(DESIGN.md §12)"),
    ("snapshot", "fig_snapshot",
     "Wait-free snapshot — epoch-ring resolution vs retry loop under a "
     "100%-mutation adversary (DESIGN.md §13)"),
    ("recovery", "fig_recovery",
     "Durable ingest — WAL append overhead + recovery wall-time vs "
     "checkpoint cadence (DESIGN.md §16)"),
)

REQUIRED_KEYS = {
    "figure": str,
    "q": (int,),
    "engine": str,
    "seconds": (int, float),
    "steps": (int, float),
    "steps_per_s": (int, float),
    "speedup_vs_baseline": (int, float),
}


def validate_records(records: list[dict], expect_figures) -> list[str]:
    """Schema check for the uniform long format; returns human-readable
    failures (empty = valid)."""
    errors = []
    seen = set()
    for i, rec in enumerate(records):
        for key, types in REQUIRED_KEYS.items():
            if key not in rec:
                errors.append(f"record {i}: missing key {key!r} ({rec})")
            elif not isinstance(rec[key], types):
                errors.append(f"record {i}: {key}={rec[key]!r} is not {types}")
        if isinstance(rec.get("figure"), str):
            seen.add(rec["figure"])
    for name in expect_figures:
        if not any(fig == name or fig.startswith(name + "_") for fig in seen):
            errors.append(f"registered figure {name!r} emitted no records "
                          f"(saw {sorted(seen)})")
    return errors


def write_bench_files(records: list[dict], root: pathlib.Path = ROOT) -> list[str]:
    """One BENCH_<figure>.json per figure at the repo root — the
    longitudinal perf record the ROADMAP's trajectory is judged by."""
    by_fig: dict[str, list[dict]] = {}
    for rec in records:
        by_fig.setdefault(rec["figure"], []).append(rec)
    written = []
    for fig, rows in sorted(by_fig.items()):
        path = root / f"BENCH_{fig}.json"
        with open(path, "w", encoding="utf-8") as f:
            json.dump(rows, f, indent=1)
        written.append(str(path))
    return written


def check_committed_records(figures=None, root: pathlib.Path = ROOT
                            ) -> tuple[list[str], list[str]]:
    """Validate the COMMITTED BENCH_<figure>.json records for the registered
    figures. Returns (errors, notes).

    A figure with no committed record yet is a NOTE, never an error: a
    fresh clone (or a newly registered figure whose first full ``--json``
    run hasn't landed) must not abort ``--quick``/``--smoke`` — only a
    record that EXISTS but is unreadable or schema-invalid fails the gate.
    """
    errors: list[str] = []
    notes: list[str] = []
    for name in (figures if figures is not None else [f[0] for f in FIGURES]):
        # a registered name is a record-figure PREFIX: fig_sharded emits
        # sharded_apply + sharded_bfs, each with its own BENCH file
        paths = sorted(root.glob(f"BENCH_{name}.json")) \
            + sorted(root.glob(f"BENCH_{name}_*.json"))
        if not paths:
            notes.append(f"no committed BENCH_{name}*.json yet "
                         f"(fresh clone / new figure) — a full --json run "
                         f"will create it")
            continue
        for path in paths:
            fig = path.stem[len("BENCH_"):]
            try:
                with open(path, encoding="utf-8") as f:
                    rows = json.load(f)
            except (OSError, json.JSONDecodeError) as e:
                errors.append(f"{path.name}: unreadable ({e})")
                continue
            if not isinstance(rows, list) or not rows:
                errors.append(f"{path.name}: expected a non-empty record "
                              f"list, got {type(rows).__name__}")
                continue
            errors += [f"{path.name}: {e}"
                       for e in validate_records(rows, [fig])]
    return errors, notes


def preflight(root: pathlib.Path = ROOT) -> list[str]:
    """--smoke import-and-registry preflight (DESIGN.md §15): every
    registered figure module must exist under benchmarks/, import
    cleanly, and expose the ``main`` entry the driver is about to call —
    so a broken import or a FIGURES typo fails the gate in milliseconds
    instead of mid-sweep. Built on repro.analysis.modwalk, the analysis
    framework's module-walking helper."""
    src = str(root / "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    from repro.analysis.modwalk import iter_package_modules, preflight_imports

    on_disk = {name for name, _ in
               iter_package_modules(root / "benchmarks", "benchmarks")}
    registered = [f"benchmarks.{module}" for _, module, _ in FIGURES]
    errors = [f"{mod}: registered in FIGURES but no such module under "
              f"benchmarks/" for mod in registered if mod not in on_disk]
    errors += preflight_imports([m for m in registered if m in on_disk],
                                require_attr="main")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write sweep rows as uniform JSON records")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: quick sweeps only, assert every figure "
                         "emits schema-valid JSON")
    args = ap.parse_args()
    quick = args.quick or args.smoke

    if args.smoke:
        failures = preflight()
        if failures:
            print("\n".join(failures), file=sys.stderr)
            sys.exit(1)
        print(f"preflight: {len(FIGURES)} registered figure modules "
              f"import cleanly and expose main()")

    csv: list[str] = []
    json_records: list[dict] = []

    import importlib

    for _name, module, banner in FIGURES:
        print("=" * 72)
        print(banner)
        print("=" * 72)
        mod = importlib.import_module(f"benchmarks.{module}")
        csv += mod.main(quick=quick, rows_out=json_records)
        print()

    if not args.smoke:
        print("=" * 72)
        print("BFS kernel — structural intensity + jnp-path wall time")
        print("=" * 72)
        from benchmarks import kernel_bench
        csv += kernel_bench.main(quick=quick)

        print("\n" + "=" * 72)
        print("Roofline — per (arch x shape), single-pod 256 chips "
              "(see EXPERIMENTS.md)")
        print("=" * 72)
        from benchmarks import roofline
        rows = roofline.build_table()
        print(roofline.format_table(rows))
        # roofline rides the same long-format record stream (and hence the
        # committed BENCH_roofline.json on full --json runs, DESIGN.md §14)
        json_records += roofline.records(rows)
        for r in rows:
            if not r.get("skipped"):
                csv.append(f'roofline/{r["arch"]}/{r["shape"]},'
                           f'{r["compute_s"]*1e6:.1f},'
                           f'dominant={r["dominant"]};frac={r["roofline_fraction"]:.3f}')

        print("\n" + "=" * 72)
        print("CSV (name,us_per_call,derived)")
        print("=" * 72)
        for line in csv:
            print(line)

    if args.smoke or (args.json and not quick):
        # one schema gate guards both the CI smoke check and the committed
        # longitudinal BENCH records a full --json run is about to write
        errors = validate_records(json_records, [f[0] for f in FIGURES])
        if errors:
            print("\n".join(errors), file=sys.stderr)
            sys.exit(1)
        print(f"{len(json_records)} records from {len(FIGURES)} figures "
              f"— schema valid")
        # committed-record audit: schema-check the BENCH files that exist;
        # a missing record (fresh clone / newly registered figure) is only
        # a note — quick/smoke must never abort on it
        cerrors, notes = check_committed_records()
        for note in notes:
            print(f"note: {note}")
        if cerrors:
            print("\n".join(cerrors), file=sys.stderr)
            sys.exit(1)

    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(json_records, f, indent=1)
        print(f"\nwrote {len(json_records)} sweep records to {args.json}")
        if quick:
            # quick/smoke numbers are not comparable run-to-run: never let
            # them clobber the committed longitudinal BENCH records
            print("quick/smoke run: BENCH_<figure>.json records not updated")
        else:
            for path in write_bench_files(json_records):
                print(f"wrote {path}")


if __name__ == "__main__":
    main()
