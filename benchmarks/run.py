"""Benchmark driver — one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV lines per the harness contract.
  python -m benchmarks.run [--quick] [--json PATH]

``--json`` additionally writes the sweep figures' rows as one uniform
long-format record list ({figure, q, engine, seconds, steps, steps_per_s,
speedup_vs_baseline}) — every figure exposing ``json_rows`` feeds the same
schema, so downstream plotting aggregates them without per-figure cases.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write sweep rows as uniform JSON records")
    args = ap.parse_args()

    csv: list[str] = []
    json_records: list[dict] = []

    print("=" * 72)
    print("Fig. 9 analogue — throughput vs lanes, 3 mixes, no GetPath")
    print("=" * 72)
    from benchmarks import fig9_throughput
    csv += fig9_throughput.main(quick=args.quick)

    print("\n" + "=" * 72)
    print("Fig. 10 analogue — mixes + 2% GetPath (double-collect sessions)")
    print("=" * 72)
    from benchmarks import fig10_getpath
    csv += fig10_getpath.main(quick=args.quick)

    print("\n" + "=" * 72)
    print("Multi-query analogue — fused multi-source BFS vs vmap, Q sweep")
    print("=" * 72)
    from benchmarks import fig_multiquery
    csv += fig_multiquery.main(quick=args.quick, rows_out=json_records)

    print("\n" + "=" * 72)
    print("Sharded analogue — mesh-partitioned engines vs dense (DESIGN.md §8)")
    print("=" * 72)
    from benchmarks import fig_sharded
    csv += fig_sharded.main(quick=args.quick, rows_out=json_records)

    print("\n" + "=" * 72)
    print("BFS kernel — structural intensity + jnp-path wall time")
    print("=" * 72)
    from benchmarks import kernel_bench
    csv += kernel_bench.main(quick=args.quick)

    print("\n" + "=" * 72)
    print("Roofline — per (arch x shape), single-pod 256 chips (see EXPERIMENTS.md)")
    print("=" * 72)
    from benchmarks import roofline
    rows = roofline.build_table()
    print(roofline.format_table(rows))
    for r in rows:
        if not r.get("skipped"):
            csv.append(f'roofline/{r["arch"]}/{r["shape"]},'
                       f'{r["compute_s"]*1e6:.1f},'
                       f'dominant={r["dominant"]};frac={r["roofline_fraction"]:.3f}')

    print("\n" + "=" * 72)
    print("CSV (name,us_per_call,derived)")
    print("=" * 72)
    for line in csv:
        print(line)

    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(json_records, f, indent=1)
        print(f"\nwrote {len(json_records)} sweep records to {args.json}")


if __name__ == "__main__":
    main()
