"""Roofline table generator: dry-run JSONL + analytic model -> §Roofline rows.

Three terms per (arch x shape) on the single-pod 256-chip mesh:
  compute    = impl_FLOPs / (256 x 197e12)
  memory     = HBM_bytes_per_chip / 819e9
  collective = collective_bytes_per_chip / 50e9

impl terms come from benchmarks/analytic.py (exact op-level model of this
implementation — XLA's cost analysis counts scanned layer bodies once, see
analytic.py docstring); the dry-run's HLO flops / bytes / parsed collective
bytes are reported alongside as compiled-artifact evidence. Roofline
fraction = (MODEL_FLOPS time) / max(term) — the §Perf score.
"""
from __future__ import annotations

import json
import os
import sys

from benchmarks.analytic import CHIPS, roofline_terms
from repro.configs import ARCHS, SHAPES, get_config


def load_dryrun(path: str) -> dict:
    recs = {}
    if not os.path.exists(path):
        return recs
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def build_table(dryrun_path: str = "results/dryrun_final.jsonl",
                microbatch_map: dict | None = None):
    if not os.path.exists(dryrun_path):
        dryrun_path = "results/dryrun_baseline.jsonl"
    recs = load_dryrun(dryrun_path)
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape in cfg.skip_shapes:
                rows.append(dict(arch=arch, shape=shape, skipped=True))
                continue
            rec = recs.get((arch, shape, "single"), {})
            mb = rec.get("microbatches", 1) or 1
            t = roofline_terms(arch, shape, microbatches=mb)
            row = dict(arch=arch, shape=shape, skipped=False, microbatches=mb, **t)
            if rec.get("ok"):
                mem = rec.get("memory", {})
                row["hlo_flops"] = rec.get("cost", {}).get("flops")
                row["hlo_bytes"] = rec.get("cost", {}).get("bytes accessed")
                row["hlo_coll_bytes"] = sum(
                    v for k, v in rec.get("collectives", {}).items() if k != "count")
                row["hlo_coll_count"] = rec.get("collectives", {}).get("count")
                row["device_temp_gb"] = mem.get("temp_bytes", 0) / 1e9
                row["device_args_gb"] = mem.get("argument_bytes", 0) / 1e9
                row["fits_hbm"] = (mem.get("temp_bytes", 0) + mem.get("argument_bytes", 0)) < 16e9
                row["compile_s"] = rec.get("compile_s")
            rows.append(row)
    return rows


def format_table(rows) -> str:
    hdr = (f'{"arch":24s} {"shape":12s} {"mb":>3s} {"compute":>9s} {"memory":>9s} '
           f'{"collectv":>9s} {"bound":>10s} {"useful":>7s} {"roofline":>9s} {"fits":>5s}')
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("skipped"):
            out.append(f'{r["arch"]:24s} {r["shape"]:12s}  -- skipped (full attention; see DESIGN.md §5)')
            continue
        out.append(
            f'{r["arch"]:24s} {r["shape"]:12s} {r["microbatches"]:3d} '
            f'{r["compute_s"]*1e3:8.2f}m {r["memory_s"]*1e3:8.2f}m '
            f'{r["collective_s"]*1e3:8.2f}m {r["dominant"]:>10s} '
            f'{r["useful_ratio"]:7.2%} {r["roofline_fraction"]:8.2%} '
            f'{"yes" if r.get("fits_hbm") else "NO":>5s}')
    return "\n".join(out)


def records(rows) -> list[dict]:
    """Long-format BENCH records for the roofline table — the uniform
    schema of benchmarks/run.py ({figure, q, engine, seconds, steps,
    steps_per_s, speedup_vs_baseline} + extras), so a full ``--json`` run
    commits ``BENCH_roofline.json`` next to the sweep figures and the
    perf trajectory covers the analytic model too (DESIGN.md §14).

    Mapping: one record per runnable (arch x shape) cell; ``seconds`` is
    the binding roofline term (the modeled step time), ``q`` the
    microbatch count, and ``speedup_vs_baseline`` the roofline fraction —
    the cell's §Perf score, already a ratio-to-ideal.
    """
    out = []
    for r in rows:
        if r.get("skipped"):
            continue
        bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        out.append({
            "figure": "roofline",
            "q": int(r["microbatches"]),
            "engine": f'{r["arch"]}/{r["shape"]}',
            "seconds": float(bound_s),
            "steps": 1,
            "steps_per_s": 1.0 / bound_s if bound_s > 0 else 0.0,
            "speedup_vs_baseline": float(r["roofline_fraction"]),
            "compute_s": float(r["compute_s"]),
            "memory_s": float(r["memory_s"]),
            "collective_s": float(r["collective_s"]),
            "dominant": r["dominant"],
            "useful_ratio": float(r["useful_ratio"]),
        })
    return out


def main(out_json: str | None = None):
    rows = build_table()
    print(format_table(rows))
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    # csv lines for run.py contract
    for r in rows:
        if not r.get("skipped"):
            print(f'roofline/{r["arch"]}/{r["shape"]},'
                  f'{r["compute_s"]*1e6:.1f},'
                  f'dominant={r["dominant"]};frac={r["roofline_fraction"]:.3f}')
    return rows


if __name__ == "__main__":
    main(out_json=sys.argv[1] if len(sys.argv) > 1 else "results/roofline.json")
