"""Paper Fig. 10 analogue: workload mixes WITH 2% GetPath reachability queries.

Reproduces the paper's second experiment set: the same three mixes with 2%
GetPath (the paper caps queries at 2% "considering that its overhead in
comparison to other operations is significant"). Queries run the
double-collect session against the live state between mutation batches —
the obstruction-free protocol, so we also report the mean collect-rounds
per query (2 = clean double collect; >2 = retries forced by concurrent
mutations), which is the paper's progress story quantified.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import apply_ops_fast, apply_ops, get_path_session, make_op_batch
from benchmarks.fig9_throughput import MIXES, gen_ops, seed_graph


def run_mix(engine, g0, mix, lanes, nv, *, total_ops=2048, getpath_frac=0.02, seed=2):
    rng = np.random.default_rng(seed)
    state = {"g": g0}
    n_ops = 0
    n_queries = 0
    rounds = 0
    found = 0
    # warmup (engine AND the query path's collect/compare jits)
    engine(g0, make_op_batch(gen_ops(rng, mix, lanes, nv), lanes))
    get_path_session(lambda: g0, 0, 1, max_rounds=4)
    t0 = time.perf_counter()
    while n_ops < total_ops:
        batch = make_op_batch(gen_ops(rng, mix, lanes, nv), lanes)
        state["g"], _ = engine(state["g"], batch)
        n_ops += lanes
        if rng.random() < getpath_frac * lanes:
            s, d = (int(x) for x in rng.integers(0, nv, 2))
            pr = get_path_session(lambda: state["g"], s, d, max_rounds=16)
            n_queries += 1
            rounds += int(pr.rounds)
            found += int(bool(pr.found))
    jax.block_until_ready(state["g"].adj_packed)
    dt = time.perf_counter() - t0
    return ((n_ops + n_queries) / dt, n_queries, rounds / max(n_queries, 1),
            found, n_ops + n_queries)


def json_rows(results, figure="fig10_getpath"):
    """Long-format records in the shared fig_multiquery schema (lanes as
    ``q``, coarselock as baseline; extra columns carry the query stats)."""
    out = []
    for (mix_name, lanes), per_engine in results.items():
        base_tput = per_engine["coarselock"][0]
        for eng, (tput, nq, avg_r, _found, steps) in per_engine.items():
            out.append({
                "figure": figure,
                "q": lanes,
                "engine": eng,
                "seconds": steps / tput,
                "steps": steps,
                "steps_per_s": tput,
                "speedup_vs_baseline": tput / base_tput,
                "mix": mix_name,
                "queries": nq,
                "rounds": avg_r,
            })
    return out


def main(quick=False, rows_out=None):
    g0, oracle, nv = seed_graph()
    total = 512 if quick else 2048
    out = []
    results = {}
    print(f'{"mix":8s} {"lanes":>6s} {"engine":>12s} {"ops/s":>10s} '
          f'{"queries":>8s} {"avg_rounds":>10s}')
    for mix_name, mix in MIXES.items():
        for lanes in (16, 64, 256):
            per_engine = {}
            for name, engine in (("nonblocking", apply_ops_fast),
                                 ("coarselock", apply_ops)):
                tput, nq, avg_r, found, steps = run_mix(
                    engine, g0, mix, lanes, nv, total_ops=total)
                per_engine[name] = (tput, nq, avg_r, found, steps)
                print(f"{mix_name:8s} {lanes:6d} {name:>12s} {tput:10.0f} "
                      f"{nq:8d} {avg_r:10.2f}")
                out.append(f"fig10/{mix_name}/{name}/lanes{lanes},"
                           f"{1e6/tput:.1f},queries={nq};rounds={avg_r:.2f}")
            results[(mix_name, lanes)] = per_engine
        if quick:
            break
    if rows_out is not None:
        rows_out.extend(json_rows(results))
    return out


if __name__ == "__main__":
    main()
