"""Serving-admission figure: coalesced multi-tenant ingest vs serial baseline.

The tentpole claim of DESIGN.md §12 quantified: N clients submitting
entity-disjoint mutation batches through the ingest pool coalesce into ONE
fused ``apply_ops_fast`` per admission round (N batches, one device
dispatch), while the serial one-batch-at-a-time baseline pays one dispatch
per client batch. Both engines are the SAME ``IngestPool`` code path — the
baseline simply runs with ``max_inflight=1``, so the measured gap is the
admission layer's coalescing, not a different apply engine — and both
replay the identical pre-drawn client programs in the identical submission
order (the linearization the property harness checks is bit-identical to
the serial replay, so the two runs end in the same state).

Sweep: clients ∈ {3, 6} (3 rounds each in quick mode). Each row records
the admission observability the regression suite pins (queue_depth_max,
wait_max_s, coalesce_max, fused_calls) next to the throughput, so the
longitudinal BENCH record keeps the *why* of a regression, not just the
slowdown. Rows use the shared long-format JSON schema (``q`` = clients).
"""
from __future__ import annotations

import time

import jax

from repro.core import make_graph
from repro.runtime.ingest import IngestPool

CLIENTS = (3, 6)
LANES = 4          # lanes per client batch (2 AddV + 2 AddE)
CAP = 256


def client_programs(clients: int, batches: int):
    """Entity-disjoint per-client programs: client c works a private key
    block, each batch adding a fresh 2-vertex edge pair chained to the
    previous one — disjoint footprints, so every round coalesces fully."""
    from repro.core import OP_ADD_E, OP_ADD_V

    progs = {}
    for c in range(clients):
        base = 1000 * (c + 1)
        prog = []
        for j in range(batches):
            a, b = base + 2 * j, base + 2 * j + 1
            ops = [(OP_ADD_V, a), (OP_ADD_V, b), (OP_ADD_E, a, b)]
            ops.append((OP_ADD_E, a - 2, a) if j else (OP_ADD_E, b, a))
            prog.append(ops)
        progs[f"c{c}"] = prog
    return progs


def _serve(progs, batches: int, max_inflight: int):
    """Replay the programs round-robin: one pump per submission round —
    coalesced admission fuses the round into one apply; the max_inflight=1
    baseline is forced to take one round (one fused call) per batch."""
    pool = IngestPool(make_graph(CAP), max_inflight=max_inflight)
    for j in range(batches):
        for cid, prog in progs.items():
            pool.submit(cid, prog[j])
        pool.pump()
    pool.flush()
    jax.block_until_ready(pool.snapshot().adj_packed)
    assert pool.stats.applied == len(progs) * batches
    assert pool.stats.retries == 0          # disjoint: nothing ever conflicts
    return pool.stats


def _time(fn, reps):
    fn()  # warmup: jit the fused shapes this workload produces
    t0 = time.perf_counter()
    last = None
    for _ in range(reps):
        last = fn()
    return (time.perf_counter() - t0) / reps, last


def run_sweep(*, reps=3, quick=False):
    batches = 4 if quick else 12
    rows = []
    for clients in CLIENTS[:1] if quick else CLIENTS:
        progs = client_programs(clients, batches)
        t_coal, s_coal = _time(lambda: _serve(progs, batches, 8), reps)
        t_serial, s_serial = _time(lambda: _serve(progs, batches, 1), reps)
        steps = clients * batches           # client batches admitted
        rows.append({
            "clients": clients,
            "batches": batches,
            "coalesced_s": t_coal,
            "serial_s": t_serial,
            "steps": steps,
            "coalesced_steps_per_s": steps / t_coal,
            "serial_steps_per_s": steps / t_serial,
            "speedup": t_serial / t_coal,
            "coalesced_stats": s_coal,
            "serial_stats": s_serial,
        })
    return rows


def json_rows(rows, figure="serving"):
    """Long-format records in the shared schema (``q`` = client count),
    plus the admission observability columns the stats suite pins."""
    out = []
    for r in rows:
        for eng in ("coalesced", "serial"):
            s = r[f"{eng}_stats"]
            out.append({
                "figure": figure,
                "q": r["clients"],
                "engine": eng,
                "seconds": r[f"{eng}_s"],
                "steps": r["steps"],
                "steps_per_s": r[f"{eng}_steps_per_s"],
                "speedup_vs_baseline": r["serial_s"] / r[f"{eng}_s"],
                "fused_calls": s.fused_calls,
                "coalesce_max": s.coalesce_max,
                "queue_depth_max": s.queue_depth_max,
                "wait_max_s": s.wait_max_s,
            })
    return out


def main(quick=False, rows_out=None):
    out = []
    print(f'{"clients":>7s} {"engine":>10s} {"ms/run":>10s} '
          f'{"batches/s":>11s} {"speedup":>8s} {"fused":>6s} {"qmax":>5s} '
          f'{"waitmax_ms":>11s}')
    rows = run_sweep(quick=quick)
    if rows_out is not None:
        rows_out.extend(json_rows(rows))
    for r in rows:
        for eng in ("coalesced", "serial"):
            s = r[f"{eng}_stats"]
            sp = f'{r["speedup"]:7.2f}x' if eng == "coalesced" else f'{"":>8s}'
            print(f'{r["clients"]:7d} {eng:>10s} {r[f"{eng}_s"]*1e3:10.2f} '
                  f'{r[f"{eng}_steps_per_s"]:11.0f} {sp} '
                  f'{s.fused_calls:6d} {s.queue_depth_max:5d} '
                  f'{s.wait_max_s*1e3:11.2f}')
            out.append(f'serving/{eng}/c{r["clients"]},'
                       f'{r[f"{eng}_s"]*1e6:.1f},'
                       f'batches_per_s={r[f"{eng}_steps_per_s"]:.0f};'
                       f'fused_calls={s.fused_calls};'
                       f'queue_depth_max={s.queue_depth_max};'
                       f'wait_max_ms={s.wait_max_s*1e3:.2f}'
                       + (f';speedup_vs_serial={r["speedup"]:.2f}'
                          if eng == "coalesced" else ""))
    return out


if __name__ == "__main__":
    main()
