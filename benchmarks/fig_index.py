"""Reachability-index figure: index fast path vs fused BFS, read-heavy sweep.

The serving claim of DESIGN.md §9 quantified: a batch of Q reachability
queries against a FRESH index costs one O(V) version compare plus one
[Q, L] label_join contraction, while the fused-BFS session pays a
double collect — two multi-superstep [Q,V] @ [V,V] traversals. The sweep
crosses Q ∈ {16, 64} with the mutation rate (mutations per query) in
{0, 1%, 10%}: every mutation round dirties the epoch, forcing the index
engine to pay an incremental ``refresh`` (re-traversing only the affected
landmark closures) before it can serve again, while the fused engine's
cost is mutation-oblivious. Both engines replay the IDENTICAL pre-drawn
workload schedule.

Expected shape: the index engine wins by a widening margin as the query
share grows (read-heavy serving — the regime the ROADMAP's
millions-of-users query mix lives in), and degrades toward parity as
mutations approach the query rate and refresh dominates. Rows use the
fig_multiquery long-format JSON schema (plus a ``mut`` column) so
benchmarks/run.py --json aggregates every figure uniformly.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import (
    OP_ADD_E, OP_ADD_V, apply_ops_fast, get_paths_session, make_graph,
    make_op_batch,
)
from repro.index import build_index, index_fresh, reach_session, refresh
from benchmarks.fig9_throughput import gen_ops

QS = (16, 64)
MUTS = (0.0, 0.01, 0.1)
ENGINES = ("index", "fused")
MIX = (1, 1, 0, 6, 4, 0)          # mutating mix: mostly edge flips


def seed_sparse_graph(nv=200, cap=256, ne=320, seed=9):
    """Moderate-density serving graph (~1.6 avg out-degree): reachability is
    varied (not one giant SCC) and landmark closures are shallow — the
    regime where incremental refresh re-traverses few landmarks."""
    rng = np.random.default_rng(seed)
    g = make_graph(cap)
    ops = [(OP_ADD_V, k) for k in range(nv)]
    ops += [(OP_ADD_E, int(a), int(b)) for a, b in rng.integers(0, nv, (ne, 2))]
    for i in range(0, len(ops), 256):
        g, _ = apply_ops_fast(g, make_op_batch(ops[i:i + 256], 256))
    return g, nv


def make_schedule(rng, q, mut, nv, rounds):
    """Pre-draw (mutation ops or None, Q query pairs) per round so both
    engines serve the exact same traffic. The mutated-lane count per round
    is Binomial(q, mut), so ``mut`` really is the expected mutations per
    query across the whole schedule (no saturation at high mut * q)."""
    sched = []
    for _ in range(rounds):
        k = int(rng.binomial(q, mut))
        ops = gen_ops(rng, MIX, k, nv) if k else None
        pairs = [tuple(int(x) for x in rng.integers(0, nv, 2))
                 for _ in range(q)]
        sched.append((ops, pairs))
    return sched


def _serve_index(g0, idx0, sched):
    state = {"g": g0}
    idx = idx0
    hits = misses = refreshes = 0
    for ops, pairs in sched:
        if ops is not None:
            state["g"], _ = apply_ops_fast(state["g"], make_op_batch(ops))
        if not index_fresh(idx, state["g"]):
            idx, _ = refresh(idx, state["g"])
            refreshes += 1
        res = reach_session(lambda: state["g"], idx, pairs)
        hits += res.from_index
        misses += res.fellback
    jax.block_until_ready(state["g"].adj_packed)
    return hits, misses, refreshes


def _serve_fused(g0, sched):
    state = {"g": g0}
    for ops, pairs in sched:
        if ops is not None:
            state["g"], _ = apply_ops_fast(state["g"], make_op_batch(ops))
        get_paths_session(lambda: state["g"], pairs)
    jax.block_until_ready(state["g"].adj_packed)


def _time(fn, reps):
    fn()  # warmup: jit everything on this workload shape
    t0 = time.perf_counter()
    last = None
    for _ in range(reps):
        last = fn()
    return (time.perf_counter() - t0) / reps, last


def run_sweep(*, reps=3, seed=11, quick=False):
    g0, nv = seed_sparse_graph()
    idx0 = build_index(g0)     # serving starts warm: build cost is amortized
    rounds = 3 if quick else 8
    rows = []
    for q in QS[:1] if quick else QS:
        for mut in MUTS[:2] if quick else MUTS:
            sched = make_schedule(np.random.default_rng(seed), q, mut, nv,
                                  rounds)
            t_index, (hits, misses, refreshes) = _time(
                lambda: _serve_index(g0, idx0, sched), reps)
            t_fused, _ = _time(lambda: _serve_fused(g0, sched), reps)
            steps = rounds * q
            rows.append({
                "q": q,
                "mut": mut,
                "index_s": t_index,
                "fused_s": t_fused,
                "steps": steps,
                "index_steps_per_s": steps / t_index,
                "fused_steps_per_s": steps / t_fused,
                "speedup": t_fused / t_index,
                "hits": hits,
                "misses": misses,
                "refreshes": refreshes,
            })
    return rows


def json_rows(rows, figure="index", engines=ENGINES):
    """Long-format records in the schema shared with fig_multiquery /
    fig_sharded (DESIGN.md §9 figure), plus the ``mut`` sweep column."""
    out = []
    for r in rows:
        base_s = r[f"{engines[-1]}_s"]
        for eng in engines:
            out.append({
                "figure": figure,
                "q": r["q"],
                "engine": eng,
                "seconds": r[f"{eng}_s"],
                "steps": r["steps"],
                "steps_per_s": r[f"{eng}_steps_per_s"],
                "speedup_vs_baseline": base_s / r[f"{eng}_s"],
                "mut": r["mut"],
            })
    return out


def main(quick=False, rows_out=None):
    out = []
    print(f'{"Q":>4s} {"mut":>6s} {"engine":>6s} {"ms/round":>10s} '
          f'{"queries/s":>12s} {"speedup":>8s} {"hit/miss/refresh":>18s}')
    rows = run_sweep(quick=quick)
    if rows_out is not None:
        rows_out.extend(json_rows(rows))
    for r in rows:
        hmr = f'{r["hits"]}/{r["misses"]}/{r["refreshes"]}'
        print(f'{r["q"]:4d} {r["mut"]:6.2f} {"index":>6s} '
              f'{r["index_s"]*1e3:10.2f} {r["index_steps_per_s"]:12.0f} '
              f'{r["speedup"]:7.2f}x {hmr:>18s}')
        print(f'{r["q"]:4d} {r["mut"]:6.2f} {"fused":>6s} '
              f'{r["fused_s"]*1e3:10.2f} {r["fused_steps_per_s"]:12.0f} '
              f'{"":>8s} {"":>18s}')
        out.append(f'index/fast/q{r["q"]}/mut{r["mut"]},'
                   f'{r["index_s"]*1e6:.1f},'
                   f'queries_per_s={r["index_steps_per_s"]:.0f};'
                   f'speedup_vs_fused={r["speedup"]:.2f};'
                   f'hits={r["hits"]};misses={r["misses"]}')
        out.append(f'index/fused_ref/q{r["q"]}/mut{r["mut"]},'
                   f'{r["fused_s"]*1e6:.1f},'
                   f'queries_per_s={r["fused_steps_per_s"]:.0f}')
    return out


if __name__ == "__main__":
    main()
