"""Paper Fig. 9 analogue: graph-op throughput vs concurrency, 3 workload mixes.

The paper plots ops/sec vs thread count for the non-blocking graph vs
sequential and coarse-lock implementations. The TPU-adapted analogue:
"threads" = lanes of a batched op stream; engines:

  nonblocking : apply_ops_fast   (disjoint-access-parallel vectorized batch)
  coarselock  : apply_ops        (device-serialized lanes — the whole batch
                                  holds the structure, like one global lock)
  sequential  : GraphOracle      (host Python, one op at a time)

Workload mixes match the paper §5 set 1 (no GetPath):
  lookup-heavy   (2.5, 2.5, 45, 2.5, 2.5, 45)%
  equal          (12.5, 12.5, 25, 12.5, 12.5, 25)%
  update-heavy   (22.5, 22.5, 5, 22.5, 22.5, 5)%
Initial graph: 1000 vertices, ~E/4 random edges (paper §5); CPU wall times —
the claim reproduced is the SCALING SHAPE (throughput grows with lanes for
the non-blocking engine, flat/declining for serialized ones).

Second sweep (DESIGN.md §11): the direction-optimizing superstep. One fused
multi-BFS superstep is timed at controlled frontier densities for the
packed top-down "push" expansion, the bottom-up "pull" word reduction over
the maintained in-adjacency, and the "hybrid" alpha/beta chooser — the
push-vs-pull crossover density is recorded on every superstep row
(median-of-10 timing; ``bench-smoke`` runs the quick form, so the hybrid
engine is part of the CI gate).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    OP_ADD_E, OP_ADD_V, OP_CON_E, OP_CON_V, OP_REM_E, OP_REM_V,
    GraphOracle, apply_ops, apply_ops_fast, make_graph, make_op_batch,
)
from repro.core.bfs import (
    DEFAULT_ALPHA,
    DEFAULT_BETA,
    multi_bfs_step_packed_jnp,
    multi_bfs_step_pull_jnp,
    pick_direction,
)
from repro.core.graph import OpBatch

MIXES = {
    "lookup": (2.5, 2.5, 45, 2.5, 2.5, 45),
    "equal": (12.5, 12.5, 25, 12.5, 12.5, 25),
    "update": (22.5, 22.5, 5, 22.5, 22.5, 5),
}
OPS = (OP_ADD_V, OP_REM_V, OP_CON_V, OP_ADD_E, OP_REM_E, OP_CON_E)


def seed_graph(nv=200, cap=256, seed=0):
    rng = np.random.default_rng(seed)
    g = make_graph(cap)
    ops = [(OP_ADD_V, k) for k in range(nv)]
    ne = nv * nv // 16
    ops += [(OP_ADD_E, int(a), int(b))
            for a, b in rng.integers(0, nv, (ne, 2))]
    for i in range(0, len(ops), 256):
        g, _ = apply_ops_fast(g, make_op_batch(ops[i:i + 256], 256))
    oracle = GraphOracle(cap)
    for op in ops:
        oracle.apply(op[0], op[1], op[2] if len(op) > 2 else -1)
    return g, oracle, nv


def gen_ops(rng, mix, lanes, nv):
    probs = np.asarray(mix, np.float64) / sum(mix)
    opcodes = rng.choice(OPS, size=lanes, p=probs)
    k1 = rng.integers(0, nv, lanes)
    k2 = rng.integers(0, nv, lanes)
    return [(int(o), int(a), int(b)) for o, a, b in zip(opcodes, k1, k2)]


def bench_engine(engine, g0, mix, lanes, nv, *, total_ops=4096, seed=1):
    rng = np.random.default_rng(seed)
    batches = []
    n = 0
    while n < total_ops:
        batches.append(make_op_batch(gen_ops(rng, mix, lanes, nv), lanes))
        n += lanes
    # warmup / compile
    g, _ = engine(g0, batches[0])
    jax.block_until_ready(g.adj_packed)
    t0 = time.perf_counter()
    g = g0
    for b in batches:
        g, res = engine(g, b)
    jax.block_until_ready(g.adj_packed)
    dt = time.perf_counter() - t0
    return n / dt


def bench_oracle(oracle_proto, mix, lanes, nv, *, total_ops=4096, seed=1):
    import copy
    rng = np.random.default_rng(seed)
    oracle = copy.deepcopy(oracle_proto)
    ops = []
    while len(ops) < total_ops:
        ops += gen_ops(rng, mix, lanes, nv)
    t0 = time.perf_counter()
    for op in ops:
        oracle.apply(*op, -1)
    return len(ops) / (time.perf_counter() - t0)


def adj_meta(g):
    """Adjacency-memory metadata (DESIGN.md §10): every engine now mutates
    word-packed storage — one uint32 word RMW per edge op instead of a
    dense row/cell write — so the storage footprint rides on the records."""
    v = g.capacity
    packed_bytes = int(g.adj_packed.size * 4)
    return {
        "adj_packed_bytes": packed_bytes,
        "adj_float32_bytes": int(v * v * 4),
        "adj_compression": int(v * v * 4) / packed_bytes,
    }


# ----------------------------------------------------------------------------
# Direction-optimizing superstep sweep (DESIGN.md §11)
# ----------------------------------------------------------------------------
DENSITIES = (0.01, 0.05, 0.1, 0.25, 0.5, 0.75)
SUPERSTEP_Q = 8


def _time_median(fn, *args, reps=10):
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    # median per-call: robust to the CPU container's scheduling noise
    return float(np.median(ts)), out


def superstep_sweep(*, nv=512, out_deg=16, q=SUPERSTEP_Q, reps=None,
                    seed=7, quick=False):
    """Time ONE fused superstep per direction at controlled frontier
    densities; returns (rows, crossover_density). Engines:

      push   : packed top-down expansion (multi_bfs_step_packed_jnp)
      pull   : bottom-up word reduction over adj_in_packed
      hybrid : the alpha/beta chooser of the hybrid backend (one jitted
               lax.cond superstep — the exact program multi_bfs runs)

    The crossover is the smallest swept density where pull's median beats
    push's — the empirical anchor for the DEFAULT_ALPHA/BETA knobs.
    """
    if reps is None:
        reps = 3 if quick else 10
    rng = np.random.default_rng(seed)
    g = make_graph(nv)
    g, _ = apply_ops_fast(g, make_op_batch(
        [(OP_ADD_V, k) for k in range(nv)], nv))
    edges = [(OP_ADD_E, int(a), int(b))
             for a, b in rng.integers(0, nv, (nv * out_deg, 2))]
    for i in range(0, len(edges), 256):
        g, _ = apply_ops_fast(g, make_op_batch(edges[i:i + 256], 256))
    v = g.capacity
    alive = g.valive

    push_fn = jax.jit(lambda f, vis: multi_bfs_step_packed_jnp(
        f, g.adj_packed, alive, vis))
    pull_fn = jax.jit(lambda f, vis: multi_bfs_step_pull_jnp(
        f, g.adj_in_packed, alive, vis))

    @jax.jit
    def hybrid_fn(f, vis):
        nf = jnp.sum(f.astype(jnp.int32))
        nu = jnp.sum((alive[None, :] & ~vis).astype(jnp.int32))
        pulling = pick_direction(jnp.asarray(False), nf, nu, q * v,
                                 DEFAULT_ALPHA, DEFAULT_BETA)
        return jax.lax.cond(
            pulling,
            lambda ff, vv: multi_bfs_step_pull_jnp(
                ff, g.adj_in_packed, alive, vv),
            lambda ff, vv: multi_bfs_step_packed_jnp(
                ff, g.adj_packed, alive, vv),
            f, vis)

    densities = DENSITIES[:2] if quick else DENSITIES
    rows = []
    for d in densities:
        frontiers = jnp.asarray(rng.random((q, v)) < d) & alive[None, :]
        visited = frontiers  # mid-BFS shape: visited ⊇ frontier
        t_push, _ = _time_median(push_fn, frontiers, visited, reps=reps)
        t_pull, _ = _time_median(pull_fn, frontiers, visited, reps=reps)
        t_hyb, _ = _time_median(hybrid_fn, frontiers, visited, reps=reps)
        rows.append({"density": d, "push_s": t_push, "pull_s": t_pull,
                     "hybrid_s": t_hyb})
    crossover = next((r["density"] for r in rows
                      if r["pull_s"] < r["push_s"]), None)
    return rows, crossover


def superstep_json_rows(rows, crossover, q=SUPERSTEP_Q,
                        figure="fig9_throughput"):
    """Uniform long-format records for the superstep sweep: one row per
    engine per density, push as the baseline, the measured crossover
    density riding on every row (None while pull never wins a swept
    point)."""
    out = []
    for r in rows:
        for eng in ("push", "pull", "hybrid"):
            sec = r[f"{eng}_s"]
            out.append({
                "figure": figure,
                "q": q,
                "engine": eng,
                "seconds": sec,
                "steps": q,                      # q query-supersteps/call
                "steps_per_s": q / sec,
                "speedup_vs_baseline": r["push_s"] / sec,
                "density": r["density"],
                "crossover_density": crossover,
            })
    return out


def run(lanes_list=(1, 4, 16, 64, 256), total_ops=2048, quick=False):
    g0, oracle, nv = seed_graph()
    rows = []
    for mix_name, mix in MIXES.items():
        for lanes in lanes_list:
            tput_fast = bench_engine(apply_ops_fast, g0, mix, lanes, nv, total_ops=total_ops)
            tput_lock = bench_engine(apply_ops, g0, mix, lanes, nv, total_ops=total_ops)
            tput_seq = bench_oracle(oracle, mix, lanes, nv,
                                    total_ops=min(total_ops, 2048))
            rows.append((mix_name, lanes, tput_fast, tput_lock, tput_seq))
        if quick:
            break
    return rows, adj_meta(g0)


def json_rows(rows, total_ops, figure="fig9_throughput", meta=None):
    """Long-format records in the schema shared with fig_multiquery (one
    per engine per sweep point; lanes play the batch-size role of ``q``,
    sequential oracle is the baseline) so benchmarks/run.py --json
    aggregates all figures uniformly."""
    out = []
    for mix, lanes, f, l, s in rows:
        for eng, tput in (("nonblocking", f), ("coarselock", l),
                          ("sequential", s)):
            out.append({
                "figure": figure,
                "q": lanes,
                "engine": eng,
                "seconds": total_ops / tput,
                "steps": total_ops,
                "steps_per_s": tput,
                "speedup_vs_baseline": tput / s,
                "mix": mix,
                **(meta or {}),
            })
    return out


def main(quick=False, rows_out=None):
    total_ops = 1024 if quick else 4096
    rows, meta = run(total_ops=total_ops, quick=quick)
    if rows_out is not None:
        rows_out.extend(json_rows(rows, total_ops, meta=meta))
    print(f'{"mix":8s} {"lanes":>6s} {"nonblocking":>12s} {"coarselock":>12s} '
          f'{"sequential":>12s} {"nb/seq":>7s}')
    out = []
    for mix, lanes, f, l, s in rows:
        print(f"{mix:8s} {lanes:6d} {f:12.0f} {l:12.0f} {s:12.0f} {f/s:7.2f}x")
        out.append(f"fig9/{mix}/lanes{lanes},{1e6/f:.1f},nb_ops_s={f:.0f};vs_seq={f/s:.2f}x")

    # direction-optimizing superstep sweep (DESIGN.md §11)
    srows, crossover = superstep_sweep(quick=quick)
    if rows_out is not None:
        rows_out.extend(superstep_json_rows(srows, crossover))
    print(f'\n{"density":>8s} {"push ms":>9s} {"pull ms":>9s} '
          f'{"hybrid ms":>10s} {"hyb/push":>9s}')
    for r in srows:
        print(f'{r["density"]:8.2f} {r["push_s"]*1e3:9.3f} '
              f'{r["pull_s"]*1e3:9.3f} {r["hybrid_s"]*1e3:10.3f} '
              f'{r["push_s"]/r["hybrid_s"]:8.2f}x')
        out.append(
            f'fig9/superstep/d{int(r["density"]*100):02d},'
            f'{r["hybrid_s"]*1e6:.1f},'
            f'hybrid_vs_push={r["push_s"]/r["hybrid_s"]:.2f}x')
    print(f"push/pull crossover density: {crossover}")
    return out


if __name__ == "__main__":
    main()
