"""Snapshot figure: wait-free epoch resolution vs the retry loop under a
100%-mutation adversary (DESIGN.md §13).

The workload is the §3.5 starvation adversary at maximum rate: EVERY state
fetch the query session performs first commits (and publishes) a mutation
inside the query's dependency set — an edge toggle on the source row — so
no two consecutive collects can ever match. Under that load:

  * ``retry``    — the pre-ring bounded loop: burns its whole round budget
                   and returns NOTHING (answered=0; the unbounded paper
                   loop would simply never return, which is why the budget
                   exists). Its per-session latency is the price of giving
                   up; its round count is pinned in the BENCH record.
  * ``waitfree`` — ``on_conflict="epoch"``: same budget, then ONE extra
                   collect against the pinned published epoch answers every
                   query exactly (answered=q).

Sweep: Q ∈ {1, 4, 16} queries per session. Rows use the shared long-format
schema (``q`` = queries per session; ``steps`` = queries ANSWERED, so
steps_per_s is useful-answer throughput — 0 for the starved retry loop by
construction, which is the figure's point). ``speedup_vs_baseline`` is
retry_latency / engine_latency per-session (latency ratio, not answer
throughput, so the retry baseline stays 1.0 and finite).
"""
from __future__ import annotations

import time

import jax

from repro.core import OP_ADD_E, OP_ADD_V, OP_REM_E, get_paths_session, make_graph
from repro.runtime.ingest import IngestPool

QS = (1, 4, 16)
CHAIN = 12
CAP = 64
BUDGET = 8         # double-collect rounds before on_conflict takes over


def _make_pool() -> IngestPool:
    pool = IngestPool(make_graph(CAP), retain_epochs=64)
    for k in range(CHAIN):
        pool.submit("seed", [(OP_ADD_V, k)])
    for k in range(CHAIN - 1):
        pool.submit("seed", [(OP_ADD_E, k, k + 1)])
    pool.submit("seed", [(OP_ADD_V, 999)])   # dedicated toggle sink
    pool.flush()
    return pool


def _hostile_fetch(pool: IngestPool):
    """Publish an edge toggle on vertex 0's row before every fetch: the
    source ecnt moves between any two collects, so they can never match.
    Toggling (instead of adding fresh vertices) keeps capacity fixed —
    the measurement never crosses a grow/recompile."""
    flip = [0]

    def fetch():
        op = OP_ADD_E if flip[0] % 2 == 0 else OP_REM_E
        flip[0] += 1
        pool.submit("_adv", [(op, 0, 999)])
        pool.flush()
        return pool.snapshot()

    return fetch


def _session(pool, pairs, mode):
    st: dict = {}
    out, rounds = get_paths_session(
        _hostile_fetch(pool), pairs, max_rounds=BUDGET, on_conflict=mode,
        fetch_epoch=pool.snapshot_epoch, stats=st)
    jax.block_until_ready(pool.snapshot().adj_packed)
    answered = sum(1 for f, _ in out if f) if mode == "epoch" else 0
    assert st["starved"], "adversary failed to starve the session"
    return rounds, answered


def _time(fn, reps):
    fn()  # warmup: jit the collect shapes this workload produces
    t0 = time.perf_counter()
    last = None
    for _ in range(reps):
        last = fn()
    return (time.perf_counter() - t0) / reps, last


def run_sweep(*, reps=3, quick=False):
    rows = []
    for q in QS[:2] if quick else QS:
        pool = _make_pool()
        pairs = [(i % (CHAIN - 1), CHAIN - 1) for i in range(q)]
        t_retry, (r_retry, _) = _time(lambda: _session(pool, pairs, "retry"),
                                      reps)
        t_wf, (r_wf, answered) = _time(lambda: _session(pool, pairs, "epoch"),
                                       reps)
        assert answered == q            # the pinned epoch answers every pair
        rows.append({
            "q": q,
            "retry_s": t_retry,
            "waitfree_s": t_wf,
            "retry_rounds": r_retry,
            "waitfree_rounds": r_wf,
            "answered": answered,
        })
    return rows


def json_rows(rows, figure="snapshot"):
    out = []
    for r in rows:
        for eng, sec, rounds, answered in (
                ("retry", r["retry_s"], r["retry_rounds"], 0),
                ("waitfree", r["waitfree_s"], r["waitfree_rounds"],
                 r["answered"])):
            out.append({
                "figure": figure,
                "q": r["q"],
                "engine": eng,
                "seconds": sec,
                "steps": answered,          # queries usefully answered
                "steps_per_s": answered / sec,
                "speedup_vs_baseline": r["retry_s"] / sec,
                "rounds": rounds,
                "budget": BUDGET,
            })
    return out


def main(quick=False, rows_out=None):
    out = []
    print(f'{"q":>3s} {"engine":>9s} {"ms/session":>11s} {"rounds":>7s} '
          f'{"answered":>9s} {"lat_ratio":>10s}')
    rows = run_sweep(quick=quick)
    if rows_out is not None:
        rows_out.extend(json_rows(rows))
    for r in rows:
        for eng in ("retry", "waitfree"):
            sec = r[f"{eng}_s"]
            rounds = r[f"{eng}_rounds"]
            answered = r["answered"] if eng == "waitfree" else 0
            ratio = r["retry_s"] / sec
            print(f'{r["q"]:3d} {eng:>9s} {sec*1e3:11.2f} {rounds:7d} '
                  f'{answered:9d} {ratio:9.2f}x')
            out.append(f'snapshot/{eng}/q{r["q"]},{sec*1e6:.1f},'
                       f'rounds={rounds};answered={answered};'
                       f'lat_ratio_vs_retry={ratio:.2f}')
    return out


if __name__ == "__main__":
    main()
