"""Recovery figure: WAL overhead per admitted round and recovery wall-time
vs checkpoint cadence (DESIGN.md §16).

Workload: 3 clients × 8 lanes of edge churn per admitted round on a fixed
capacity (no auto-grow, so no recompiles inside the measurement), with the
durability stack on a tmpfs-backed directory when available — the figure
measures the *append discipline* (serialize + write + fsync syscall +
truncation bookkeeping), not the rotational latency of whatever disk the
CI runner happens to have.

Sweep: checkpoint cadence ∈ {0 (WAL only), 4, 16} rounds. Per cadence,
three engines in the shared long-format schema (``q`` = cadence):

  * ``baseline`` — the same pool with no WAL/checkpointer: the §12
    admission path as-was. speedup_vs_baseline = 1.0.
  * ``durable``  — WAL + cadence checkpoints. ``seconds`` is per-round
    wall; the record carries ``wal_append_ratio`` (WAL append-fsync
    seconds / fused-apply wall seconds, from the §14 tracing histograms)
    and the amortized checkpoint cost. The acceptance pin: at the
    default cadence the append ratio stays ≤ 10% on full runs.
  * ``recover``  — checkpoint restore + WAL replay of the durable run.
    ``steps`` is rounds replayed; ``speedup_vs_baseline`` is how much
    faster replay is than the original execution of the same suffix
    (replayed × baseline round wall / recovery wall).

Zero acknowledged-batch loss is asserted at EVERY sweep point: each batch
acked by the durable run must be present in the recovered linearization,
and the recovered head must equal the pre-close published state bit for
bit.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import OP_ADD_E, OP_ADD_V, make_graph
from repro.obs import trace
from repro.obs.metrics import GLOBAL
from repro.runtime.ingest import IngestPool
from repro.runtime.recovery import GraphCheckpointer, recover
from repro.runtime.wal import WriteAheadLog

CADENCES = (0, 4, 16)
DEFAULT_CADENCE = 16
CAP = 1024          # serving-scale table: the fused apply does real work,
KEYS = CAP - 64     # so the append ratio reflects the discipline, not a
CLIENTS = 3         # toy graph's dispatch floor
LANES = 128
RETAIN = 8
MAX_APPEND_RATIO = 0.10


def _durable_base() -> str | None:
    return "/dev/shm" if os.path.isdir("/dev/shm") else None


def _seed(pool: IngestPool) -> None:
    pool.submit("seed", [(OP_ADD_V, k) for k in range(KEYS)])
    pool.flush()


def _run_rounds(pool: IngestPool, rounds: int, rng) -> None:
    for _ in range(rounds):
        for c in range(CLIENTS):
            ops = [(OP_ADD_E, int(a), int(b))
                   for a, b in rng.integers(0, KEYS, (LANES, 2))]
            pool.submit(f"c{c}", ops)
        pool.flush()


def _fused_apply_sum() -> float:
    return float(GLOBAL.get("ingest.fused_apply_s")["sum"])


def _measure(pool: IngestPool, rounds: int, warmup: int, rng) -> dict:
    _run_rounds(pool, warmup, rng)
    wal_a0 = pool.wal.stats.append_s if pool.wal is not None else 0.0
    trace.enable()
    fused0 = _fused_apply_sum()
    t0 = time.perf_counter()
    _run_rounds(pool, rounds, rng)
    wall = time.perf_counter() - t0
    fused = _fused_apply_sum() - fused0
    trace.disable()
    wal_append = ((pool.wal.stats.append_s - wal_a0)
                  if pool.wal is not None else 0.0)
    return {"wall": wall, "fused_s": fused, "wal_append_s": wal_append}


def run_sweep(*, quick=False):
    rounds = 10 if quick else 40
    warmup = 5 if quick else 10
    cadences = CADENCES[:2] if quick else CADENCES
    rows = []

    rng = np.random.default_rng(0)
    base_pool = IngestPool(make_graph(CAP), retain_epochs=RETAIN,
                           auto_grow=False, max_coalesce_lanes=1024)
    _seed(base_pool)
    base = _measure(base_pool, rounds, warmup, rng)
    base_round = base["wall"] / rounds

    for cadence in cadences:
        with tempfile.TemporaryDirectory(dir=_durable_base()) as d:
            rng = np.random.default_rng(0)
            wal = WriteAheadLog(os.path.join(d, "wal.log"))
            ckpt = GraphCheckpointer(os.path.join(d, "ckpt"))
            pool = IngestPool(make_graph(CAP), retain_epochs=RETAIN,
                              auto_grow=False, wal=wal, ckpt=ckpt,
                              ckpt_every=cadence, max_coalesce_lanes=1024)
            _seed(pool)
            m = _measure(pool, rounds, warmup, rng)

            head = {f: np.asarray(getattr(pool._head, f)).copy()
                    for f in pool._head._fields}
            acked = sorted(b for b, t in pool.tickets.items()
                           if t.status == "applied")

            t0 = time.perf_counter()
            rec = recover(ckpt, wal, capacity=CAP, auto_grow=False,
                          retain_epochs=RETAIN)
            recover_s = time.perf_counter() - t0

            # zero acknowledged-batch loss, bit for bit — at every point
            lost = set(acked) - set(rec.linearization)
            assert not lost, f"cadence={cadence}: lost acked batches {lost}"
            assert rec.epoch == pool.epoch
            for f, want in head.items():
                np.testing.assert_array_equal(
                    np.asarray(getattr(rec.state, f)), want,
                    err_msg=f"cadence={cadence}: field {f} diverged")

            rows.append({
                "cadence": cadence,
                "rounds": rounds,
                "base_wall": base["wall"],
                "durable_wall": m["wall"],
                "fused_s": m["fused_s"],
                "wal_append_s": m["wal_append_s"],
                "append_ratio": (m["wal_append_s"] / m["fused_s"]
                                 if m["fused_s"] > 0 else 0.0),
                "wal_bytes": wal.size_bytes(),
                "ckpt_saves": int(pool.stats.ckpt_saves),
                "recover_s": recover_s,
                "replayed": rec.replayed_rounds,
                "ckpt_step": rec.ckpt_step,
            })
            if not quick and cadence == DEFAULT_CADENCE:
                assert rows[-1]["append_ratio"] <= MAX_APPEND_RATIO, (
                    f"WAL append overhead {rows[-1]['append_ratio']:.1%} "
                    f"exceeds {MAX_APPEND_RATIO:.0%} of fused-apply wall "
                    f"at the default cadence (DESIGN.md §16)")
    return rows, base_round


def json_rows(rows, base_round, figure="recovery"):
    out = []
    for r in rows:
        n = r["rounds"]
        out.append({
            "figure": figure, "q": r["cadence"], "engine": "baseline",
            "seconds": base_round * n, "steps": n,
            "steps_per_s": 1.0 / base_round,
            "speedup_vs_baseline": 1.0,
        })
        dur_round = r["durable_wall"] / n
        out.append({
            "figure": figure, "q": r["cadence"], "engine": "durable",
            "seconds": r["durable_wall"], "steps": n,
            "steps_per_s": n / r["durable_wall"],
            "speedup_vs_baseline": base_round / dur_round,
            "wal_append_ratio": r["append_ratio"],
            "wal_bytes_per_round": r["wal_bytes"] / max(1, n),
            "ckpt_saves": r["ckpt_saves"],
        })
        out.append({
            "figure": figure, "q": r["cadence"], "engine": "recover",
            "seconds": r["recover_s"], "steps": r["replayed"],
            "steps_per_s": r["replayed"] / r["recover_s"]
            if r["recover_s"] > 0 else 0.0,
            "speedup_vs_baseline": (r["replayed"] * base_round
                                    / r["recover_s"])
            if r["recover_s"] > 0 else 0.0,
            "replayed_rounds": r["replayed"],
            "ckpt_step": r["ckpt_step"] if r["ckpt_step"] is not None else -1,
            "acked_batches_lost": 0,
        })
    return out


def main(quick=False, rows_out=None):
    out = []
    rows, base_round = run_sweep(quick=quick)
    if rows_out is not None:
        rows_out.extend(json_rows(rows, base_round))
    print(f'{"cadence":>7s} {"ms/round":>9s} {"overhead":>9s} '
          f'{"append%":>8s} {"ckpts":>6s} {"recover_ms":>11s} '
          f'{"replayed":>9s}')
    for r in rows:
        dur_round = r["durable_wall"] / r["rounds"]
        overhead = dur_round / base_round - 1.0
        print(f'{r["cadence"]:7d} {dur_round*1e3:9.2f} {overhead:+8.1%} '
              f'{r["append_ratio"]:7.1%} {r["ckpt_saves"]:6d} '
              f'{r["recover_s"]*1e3:11.1f} {r["replayed"]:9d}')
        out.append(
            f'recovery/cadence{r["cadence"]},{dur_round*1e6:.1f},'
            f'append_ratio={r["append_ratio"]:.3f};'
            f'recover_ms={r["recover_s"]*1e3:.1f};'
            f'replayed={r["replayed"]};lost=0')
    print(f'(baseline {base_round*1e3:.2f} ms/round; zero acked-batch '
          f'loss asserted at every sweep point)')
    return out


if __name__ == "__main__":
    main()
