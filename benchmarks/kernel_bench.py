"""BFS kernel benchmark: structural FLOP/byte accounting + wall time of the
jnp reference path (Pallas runs in interpret mode on CPU: its wall time is
meaningless, so the derived column reports the kernel's roofline-relevant
arithmetic intensity instead — tile mat-vec FLOPs vs HBM tile traffic)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import add_edge, add_vertex, bfs, make_graph
from repro.core.bfs import bfs_step_jnp


def build_graph(v, avg_deg, seed=0):
    rng = np.random.default_rng(seed)
    g = make_graph(v)
    for k in range(v - 2):
        g, _ = add_vertex(g, k)
    for _ in range(v * avg_deg):
        a, b = rng.integers(0, v - 2, 2)
        g, _ = add_edge(g, int(a), int(b))
    return g


def bench_step(v=1024, density=0.05, iters=20):
    rng = np.random.default_rng(0)
    adj = jnp.asarray((rng.random((v, v)) < density).astype(np.uint8))
    frontier = jnp.asarray(rng.random(v) < 0.2)
    alive = jnp.ones(v, bool)
    visited = jnp.zeros(v, bool)
    f = jax.jit(bfs_step_jnp)
    r = f(frontier, adj, alive, visited)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = f(frontier, adj, alive, visited)
    jax.block_until_ready(r)
    us = (time.perf_counter() - t0) / iters * 1e6
    flops = 2 * v * v              # tile mat-vec
    bytes_hbm = v * v * 1 + v * 16  # adj int8 + vectors
    return us, flops, bytes_hbm


def bench_full_bfs(v=512, avg_deg=8):
    g = build_graph(v, avg_deg)
    r = bfs(g, jnp.int32(0), jnp.int32(-1))
    jax.block_until_ready(r.parent)
    t0 = time.perf_counter()
    for _ in range(5):
        r = bfs(g, jnp.int32(0), jnp.int32(-1))
    jax.block_until_ready(r.parent)
    us = (time.perf_counter() - t0) / 5 * 1e6
    return us, int(r.steps)


def main(quick=False):
    out = []
    for v in ((256, 1024) if quick else (256, 1024, 2048)):
        us, flops, by = bench_step(v)
        ai = flops / by
        out.append(f"bfs_step/V{v},{us:.1f},AI={ai:.2f}flop_per_byte")
        print(f"bfs_step V={v}: {us:8.1f} us/step  AI={ai:.2f} flop/B "
              f"(TPU tile mat-vec feeds MXU at {flops/1e6:.1f} MFLOP/step)")
    us, steps = bench_full_bfs()
    out.append(f"bfs_full/V512,{us:.1f},supersteps={steps}")
    print(f"bfs full V=512: {us:.1f} us ({steps} supersteps)")
    return out


if __name__ == "__main__":
    main()
