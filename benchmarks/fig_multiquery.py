"""Beyond-paper figure: batched reachability — fused engine vs vmap, Q sweep.

The fused multi-source BFS (core.bfs.multi_bfs, DESIGN.md §7) advances Q
frontiers with ONE [Q,V] @ [V,V] frontier-matrix product per superstep; the
vmap reference pays Q independent [V]·[V,V] mat-vecs. This benchmark sweeps
Q in {1, 4, 16, 64} and reports wall time per full query batch plus the
derived *query-supersteps per second* (sum over queries of per-query BFS
steps / wall), the unit in which the fused engine's advantage is
architecture-meaningful: it is the rate at which per-query frontier
expansions retire, and the fused engine retires up to Q of them per
adjacency stream.

CPU-container numbers establish the SCALING SHAPE (fused cost roughly flat
in Q until the matmul saturates, vmap cost linear in Q); on a real TPU the
same sweep exercises the MXU via kernels/bfs_multi_step.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bfs, multi_bfs
from benchmarks.fig9_throughput import seed_graph

QS = (1, 4, 16, 64)


def _vmap_multi(state, srcs, dsts, backend="jnp"):
    """The reference path: Q independent single-query BFS under vmap."""
    return jax.vmap(lambda s, d: bfs(state, s, d, backend=backend))(srcs, dsts)


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out


def run_sweep(*, backend="jnp", reps=5, seed=3, quick=False):
    g, _, nv = seed_graph()
    rng = np.random.default_rng(seed)
    rows = []
    qs = QS[:2] if quick else QS
    for q in qs:
        keys = rng.integers(0, nv, (q, 2))
        # keys are dense 0..nv-1 in seed_graph insertion order == slot order
        srcs = jnp.asarray(keys[:, 0], jnp.int32)
        dsts = jnp.asarray(keys[:, 1], jnp.int32)

        fused_fn = jax.jit(lambda s, d: multi_bfs(g, s, d, backend=backend))
        vmap_fn = jax.jit(lambda s, d: _vmap_multi(g, s, d, backend=backend))
        t_fused, m = _time(fused_fn, srcs, dsts, reps=reps)
        t_vmap, vm = _time(vmap_fn, srcs, dsts, reps=reps)
        steps_total = int(jnp.sum(m.steps))
        assert steps_total == int(jnp.sum(vm.steps)), "engines disagree on work"
        rows.append({
            "q": q,
            "fused_s": t_fused,
            "vmap_s": t_vmap,
            "steps": steps_total,
            "fused_steps_per_s": steps_total / t_fused,
            "vmap_steps_per_s": steps_total / t_vmap,
            "speedup": t_vmap / t_fused,
        })
    return rows


def json_rows(rows, figure="multiquery", engines=("fused", "vmap")):
    """Long-format JSON records (one per engine per sweep point) — the
    schema shared with fig_sharded so benchmarks/run.py --json aggregates
    all figures uniformly."""
    out = []
    for r in rows:
        base_s = r[f"{engines[-1]}_s"]
        for eng in engines:
            out.append({
                "figure": figure,
                "q": r["q"],
                "engine": eng,
                "seconds": r[f"{eng}_s"],
                "steps": r["steps"],
                "steps_per_s": r[f"{eng}_steps_per_s"],
                "speedup_vs_baseline": base_s / r[f"{eng}_s"],
            })
    return out


def main(quick=False, rows_out=None):
    out = []
    print(f'{"Q":>4s} {"engine":>6s} {"ms/batch":>10s} {"qsteps/s":>12s} '
          f'{"speedup":>8s}')
    for backend in ("jnp",):
        sweep = run_sweep(backend=backend, quick=quick)
        if rows_out is not None:
            rows_out.extend(json_rows(sweep))
        for r in sweep:
            print(f'{r["q"]:4d} {"fused":>6s} {r["fused_s"]*1e3:10.2f} '
                  f'{r["fused_steps_per_s"]:12.0f} {r["speedup"]:7.2f}x')
            print(f'{r["q"]:4d} {"vmap":>6s} {r["vmap_s"]*1e3:10.2f} '
                  f'{r["vmap_steps_per_s"]:12.0f} {"":>8s}')
            out.append(f'multiquery/fused/q{r["q"]},{r["fused_s"]*1e6:.1f},'
                       f'qsteps_per_s={r["fused_steps_per_s"]:.0f}')
            out.append(f'multiquery/vmap/q{r["q"]},{r["vmap_s"]*1e6:.1f},'
                       f'qsteps_per_s={r["vmap_steps_per_s"]:.0f};'
                       f'fused_speedup={r["speedup"]:.2f}')
    return out


if __name__ == "__main__":
    main()
