"""Beyond-paper figure: batched reachability — fused engine vs vmap, Q sweep.

The fused multi-source BFS (core.bfs.multi_bfs, DESIGN.md §7) advances Q
frontiers with ONE [Q,V] @ [V,V] frontier-matrix product per superstep; the
vmap reference pays Q independent [V]·[V,V] mat-vecs. This benchmark sweeps
Q in {1, 4, 16, 64} and reports wall time per full query batch plus the
derived *query-supersteps per second* (sum over queries of per-query BFS
steps / wall), the unit in which the fused engine's advantage is
architecture-meaningful: it is the rate at which per-query frontier
expansions retire, and the fused engine retires up to Q of them per
adjacency stream.

CPU-container numbers establish the SCALING SHAPE (fused cost roughly flat
in Q until the matmul saturates, vmap cost linear in Q); on a real TPU the
same sweep exercises the MXU via kernels/bfs_multi_step.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bfs, multi_bfs
from repro.obs import trace as obs_trace
from benchmarks.fig9_throughput import seed_graph

QS = (1, 4, 16, 64)


def _obs_columns(g, srcs, dsts):
    """Obs-derived traversal columns (DESIGN.md §14): one traced hybrid
    run per sweep point, outside the timing loop. ``capture()`` keeps the
    recorder state local, so the timed runs stay on the untraced path."""
    with obs_trace.capture() as rec:
        jax.block_until_ready(multi_bfs(g, srcs, dsts, backend="hybrid"))
    dirs = [e.get("args", {}).get("direction")
            for e in rec.events() if e["name"] == "bfs.superstep"]
    return {
        "obs_supersteps": len(dirs),
        "obs_pull_supersteps": sum(d == "pull" for d in dirs),
        "obs_direction_flips": sum(a != b for a, b in zip(dirs, dirs[1:])),
    }


def _vmap_multi(state, srcs, dsts, backend="jnp"):
    """The reference path: Q independent single-query BFS under vmap."""
    return jax.vmap(lambda s, d: bfs(state, s, d, backend=backend))(srcs, dsts)


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    # median per-call time: robust to the CPU container's scheduling noise
    return float(np.median(ts)), out


def _adj_meta(g):
    """Adjacency-memory metadata (DESIGN.md §10): the packed engines store
    AND stream uint32 words; the float32 path stores the same words but
    expands them to a dense f32 operand per superstep."""
    v = g.capacity
    packed_bytes = int(g.adj_packed.size * 4)
    unpacked_bytes = int(v * v * 4)  # the f32 matmul operand
    return {
        "adj_packed_bytes": packed_bytes,
        "adj_float32_bytes": unpacked_bytes,
        "adj_compression": unpacked_bytes / packed_bytes,
    }


def run_sweep(*, backend="jnp", reps=None, seed=3, quick=False):
    if reps is None:
        reps = 3 if quick else 10
    g, _, nv = seed_graph()
    rng = np.random.default_rng(seed)
    rows = []
    qs = QS[:2] if quick else QS
    meta = _adj_meta(g)
    for q in qs:
        keys = rng.integers(0, nv, (q, 2))
        # keys are dense 0..nv-1 in seed_graph insertion order == slot order
        srcs = jnp.asarray(keys[:, 0], jnp.int32)
        dsts = jnp.asarray(keys[:, 1], jnp.int32)

        fused_fn = jax.jit(lambda s, d: multi_bfs(g, s, d, backend=backend))
        packed_fn = jax.jit(lambda s, d: multi_bfs(g, s, d, backend="packed"))
        vmap_fn = jax.jit(lambda s, d: _vmap_multi(g, s, d, backend=backend))
        t_fused, m = _time(fused_fn, srcs, dsts, reps=reps)
        t_packed, pm = _time(packed_fn, srcs, dsts, reps=reps)
        t_vmap, vm = _time(vmap_fn, srcs, dsts, reps=reps)
        steps_total = int(jnp.sum(m.steps))
        assert steps_total == int(jnp.sum(vm.steps)), "engines disagree on work"
        assert steps_total == int(jnp.sum(pm.steps)), "packed engine disagrees"
        obs = _obs_columns(g, srcs, dsts)
        rows.append({
            **obs,
            "q": q,
            "fused_s": t_fused,
            "fused_packed_s": t_packed,
            "vmap_s": t_vmap,
            "steps": steps_total,
            "fused_steps_per_s": steps_total / t_fused,
            "fused_packed_steps_per_s": steps_total / t_packed,
            "vmap_steps_per_s": steps_total / t_vmap,
            "speedup": t_vmap / t_fused,
            "packed_vs_float": t_fused / t_packed,
            **meta,
        })
    return rows


def json_rows(rows, figure="multiquery",
              engines=("fused", "fused_packed", "vmap")):
    """Long-format JSON records (one per engine per sweep point) — the
    schema shared with fig_sharded so benchmarks/run.py --json aggregates
    all figures uniformly. The packed-adjacency memory metadata rides on
    every record (DESIGN.md §10)."""
    out = []
    for r in rows:
        base_s = r[f"{engines[-1]}_s"]
        for eng in engines:
            out.append({
                "figure": figure,
                "q": r["q"],
                "engine": eng,
                "seconds": r[f"{eng}_s"],
                "steps": r["steps"],
                "steps_per_s": r[f"{eng}_steps_per_s"],
                "speedup_vs_baseline": base_s / r[f"{eng}_s"],
                "adj_packed_bytes": r["adj_packed_bytes"],
                "adj_float32_bytes": r["adj_float32_bytes"],
                "adj_compression": r["adj_compression"],
                # obs-derived traversal columns (DESIGN.md §14)
                "obs_supersteps": r["obs_supersteps"],
                "obs_pull_supersteps": r["obs_pull_supersteps"],
                "obs_direction_flips": r["obs_direction_flips"],
            })
    return out


def main(quick=False, rows_out=None):
    out = []
    print(f'{"Q":>4s} {"engine":>12s} {"ms/batch":>10s} {"qsteps/s":>12s} '
          f'{"speedup":>8s}')
    for backend in ("jnp",):
        sweep = run_sweep(backend=backend, quick=quick)
        if rows_out is not None:
            rows_out.extend(json_rows(sweep))
        for r in sweep:
            print(f'{r["q"]:4d} {"fused":>12s} {r["fused_s"]*1e3:10.2f} '
                  f'{r["fused_steps_per_s"]:12.0f} {r["speedup"]:7.2f}x')
            print(f'{r["q"]:4d} {"fused_packed":>12s} '
                  f'{r["fused_packed_s"]*1e3:10.2f} '
                  f'{r["fused_packed_steps_per_s"]:12.0f} '
                  f'{r["packed_vs_float"]:6.2f}xf')
            print(f'{r["q"]:4d} {"vmap":>12s} {r["vmap_s"]*1e3:10.2f} '
                  f'{r["vmap_steps_per_s"]:12.0f} {"":>8s}')
            out.append(f'multiquery/fused/q{r["q"]},{r["fused_s"]*1e6:.1f},'
                       f'qsteps_per_s={r["fused_steps_per_s"]:.0f}')
            out.append(f'multiquery/fused_packed/q{r["q"]},'
                       f'{r["fused_packed_s"]*1e6:.1f},'
                       f'qsteps_per_s={r["fused_packed_steps_per_s"]:.0f};'
                       f'vs_float={r["packed_vs_float"]:.2f}x;'
                       f'adj_compression={r["adj_compression"]:.0f}x')
            out.append(f'multiquery/vmap/q{r["q"]},{r["vmap_s"]*1e6:.1f},'
                       f'qsteps_per_s={r["vmap_steps_per_s"]:.0f};'
                       f'fused_speedup={r["speedup"]:.2f}')
    return out


if __name__ == "__main__":
    main()
