"""Durable graph-serving entrypoint: WAL-backed ingest with crash/recover.

Runs a multi-client mutation workload through ``GraphCoServer`` with the
write-ahead log + cadence checkpoints enabled (DESIGN.md §16), reporting
one fsynced JSON line per admitted round — the externally visible "ack"
record a client of this process would hold. Two modes compose into the
kill -9 round-trip the recovery-tests CI job runs:

  # serve 12 rounds, checkpoint every 4, SIGKILL ourselves after round 7:
  PYTHONPATH=src python launch/serve.py --wal-dir /tmp/d --ckpt-every 4 \\
      --steps 12 --crash-at-step 7 --report /tmp/d/report.jsonl

  # come back up from checkpoint + WAL replay and keep serving:
  PYTHONPATH=src python launch/serve.py --wal-dir /tmp/d --recover \\
      --steps 3 --report /tmp/d/report.jsonl

The crash is a real ``os.kill(getpid(), SIGKILL)`` — no interpreter
cleanup, no atexit, exactly the failure the WAL discipline claims to
survive. The driver (tests/test_recovery.py) asserts every round acked
before the kill is present in the recovered linearization (zero
acknowledged-batch loss) and that serving resumes past the crash epoch.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import OP_ADD_E, OP_ADD_V, OP_REM_E  # noqa: E402
from repro.runtime.serve_loop import GraphCoServer  # noqa: E402


def _report_line(f, payload: dict) -> None:
    """One durable JSONL record: the process may be SIGKILLed right after
    this returns, so flush + fsync before handing the ack to the driver."""
    f.write(json.dumps(payload) + "\n")
    f.flush()
    os.fsync(f.fileno())


def _client_batches(rng: np.random.Generator, clients: int, lanes: int,
                    keys: int) -> list[tuple[str, list]]:
    out = []
    for c in range(clients):
        ops = []
        for _ in range(lanes):
            r = rng.random()
            a, b = (int(x) for x in rng.integers(0, keys, 2))
            if r < 0.35:
                ops.append((OP_ADD_V, a))
            elif r < 0.85:
                ops.append((OP_ADD_E, a, b))
            else:
                ops.append((OP_REM_E, a, b))
        out.append((f"c{c}", ops))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--wal-dir", required=True,
                    help="directory for wal.log + ckpt/ (created if absent)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint cadence in admitted rounds (0 = never)")
    ap.add_argument("--steps", type=int, default=8,
                    help="admission rounds to serve")
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--lanes", type=int, default=4,
                    help="ops per client batch")
    ap.add_argument("--keys", type=int, default=24,
                    help="entity key space for the workload")
    ap.add_argument("--capacity", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--crash-at-step", type=int, default=None,
                    help="SIGKILL this process after acking round N")
    ap.add_argument("--recover", action="store_true",
                    help="restore from the wal-dir's checkpoint + WAL "
                         "before serving")
    ap.add_argument("--report", default=None,
                    help="JSONL report path (default: <wal-dir>/report.jsonl)")
    args = ap.parse_args(argv)

    os.makedirs(args.wal_dir, exist_ok=True)
    report_path = args.report or os.path.join(args.wal_dir, "report.jsonl")

    srv = GraphCoServer(capacity=args.capacity, ingest=True,
                        wal_dir=args.wal_dir, ckpt_every=args.ckpt_every)
    rng = np.random.default_rng(args.seed + (1000 if args.recover else 0))

    with open(report_path, "a") as rep:
        if args.recover:
            srv.enter_degraded()
            srv.recover_now()
            pool = srv.pool
            _report_line(rep, {
                "type": "recovered",
                "epoch": int(pool.epoch),
                "linearization": [int(b) for b in pool.linearization],
            })
            print(f"recovered at epoch {pool.epoch} "
                  f"({len(pool.linearization)} batches durable)")

        for step in range(args.steps):
            tickets = [srv.submit_client(cid, ops) for cid, ops in
                       _client_batches(rng, args.clients, args.lanes,
                                       args.keys)]
            srv.flush()
            acked = sorted(int(t.batch_id) for t in tickets
                           if t.status == "applied")
            _report_line(rep, {"type": "round", "step": step,
                               "epoch": int(srv.pool.epoch),
                               "acked": acked})
            if args.crash_at_step is not None and step == args.crash_at_step:
                # a real kill -9: no cleanup, no flushes beyond the report
                # line above — exactly what the WAL must survive
                os.kill(os.getpid(), signal.SIGKILL)

        _report_line(rep, {
            "type": "done",
            "epoch": int(srv.pool.epoch),
            "linearization": [int(b) for b in srv.pool.linearization],
        })
    print(f"served {args.steps} rounds to epoch {srv.pool.epoch}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
